"""Layer-1 Pallas kernel: one 1 ms update of a LIF + SFA neuron population.

This is the compute hot-spot of the DPSNN mini-app: advance the membrane
potential, spike-frequency-adaptation (SFA) current and refractory counter
of every neuron in a rank's population by one network time step, given the
synaptic input accumulated for this step by the coordinator.

Dynamics (per neuron, step dt = 1 ms; see DESIGN.md §7):

    i      = i_syn + i_ext                        # instantaneous PSCs (mV)
    v'     = v * decay_v + i - w        (if not refractory)
    v'     = v_reset                    (if refractory)
    spike  = (not refractory) and v' >= theta
    v''    = v_reset                    (if spike)      else v'
    w'     = w * decay_w + sfa_inc      (if spike)      else w * decay_w
    rf'    = t_ref_steps                (if spike)      else max(rf - 1, 0)

`sfa_inc` is a per-neuron vector so excitatory neurons carry adaptation
(fatigue) while inhibitory neurons have it switched off, exactly as in the
paper ("SFA is switched off for inhibitory neurons").

Scalar model parameters arrive in a tiny `params` vector (rather than being
baked into the HLO) so a single AOT artifact serves any parameterisation:

    params = [decay_v, decay_w, theta, v_reset, t_ref_steps, v_floor, 0, 0]

TPU adaptation note (DESIGN.md §3): the update is elementwise over the
neuron axis, so the kernel tiles that axis into VMEM-resident blocks via a
1-D grid; the six state/input vectors stream HBM -> VMEM once per step.
There is no MXU work — this kernel is VPU/bandwidth bound. `interpret=True`
keeps the lowering to plain HLO so the rust CPU PJRT client can run it.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Number of f32 scalars in the params vector (fixed ABI with the rust side).
N_PARAMS = 8

# Default neuron-axis block: small enough that the ~7 live f32 vectors
# (6 inputs + outputs reuse) fit comfortably in a 16 MB VMEM even with
# double-buffering headroom: 7 * 4 B * 8192 = 229 KB per block.
DEFAULT_BLOCK = 8192


def _lif_sfa_kernel(params_ref, v_ref, w_ref, rf_ref, isyn_ref, iext_ref,
                    sfa_ref, vo_ref, wo_ref, rfo_ref, sp_ref):
    decay_v = params_ref[0]
    decay_w = params_ref[1]
    theta = params_ref[2]
    v_reset = params_ref[3]
    t_ref = params_ref[4]
    v_floor = params_ref[5]

    v = v_ref[...]
    w = w_ref[...]
    rf = rf_ref[...]
    i = isyn_ref[...] + iext_ref[...]

    active = rf <= 0.0
    v_int = v * decay_v + i - w
    v_int = jnp.maximum(v_int, v_floor)  # reflecting floor (inhib. barrier)
    v_new = jnp.where(active, v_int, v_reset)
    spiked = active & (v_new >= theta)

    vo_ref[...] = jnp.where(spiked, v_reset, v_new)
    wo_ref[...] = w * decay_w + jnp.where(spiked, sfa_ref[...], 0.0)
    rfo_ref[...] = jnp.where(spiked, t_ref, jnp.maximum(rf - 1.0, 0.0))
    sp_ref[...] = spiked.astype(jnp.float32)


@partial(jax.jit, static_argnames=("block",))
def lif_sfa_step(params, v, w, rf, i_syn, i_ext, sfa_inc, *, block=DEFAULT_BLOCK):
    """Advance a population one step. All vector args are f32[n], n % block == 0.

    Returns (v', w', rf', spiked) with spiked in {0.0, 1.0}.
    """
    n = v.shape[0]
    if n % block != 0:
        raise ValueError(f"population size {n} not a multiple of block {block}")
    grid = (n // block,)
    vec = pl.BlockSpec((block,), lambda b: (b,))
    par = pl.BlockSpec((N_PARAMS,), lambda b: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(4)]
    return tuple(
        pl.pallas_call(
            _lif_sfa_kernel,
            grid=grid,
            in_specs=[par, vec, vec, vec, vec, vec, vec],
            out_specs=[vec, vec, vec, vec],
            out_shape=out_shape,
            interpret=True,  # CPU-PJRT: real-TPU lowering emits Mosaic calls
        )(params, v, w, rf, i_syn, i_ext, sfa_inc)
    )


def vmem_bytes_per_block(block=DEFAULT_BLOCK):
    """Estimated VMEM residency per grid step (for DESIGN.md §Perf)."""
    n_vectors = 6 + 4  # inputs + outputs live simultaneously
    return n_vectors * 4 * block + N_PARAMS * 4
