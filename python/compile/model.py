"""Layer-2 JAX model: the per-rank population step exported to rust.

The DPSNN coordinator (rust, layer 3) owns connectivity, delay queues and
spike exchange; the dense per-neuron dynamics — the compute hot-spot — live
here, built on the layer-1 Pallas kernel. This module is lowered once by
aot.py to HLO text; Python never runs at simulation time.

Exported signature (all f32, fixed ABI with rust/src/runtime/):

    population_step(params[8], v[n], w[n], rf[n], i_syn[n], i_ext[n],
                    sfa_inc[n]) -> (v[n], w[n], rf[n], spiked[n])
"""

import jax
import jax.numpy as jnp

from compile.kernels.lif_sfa import lif_sfa_step, DEFAULT_BLOCK, N_PARAMS


def pick_block(n, cap=DEFAULT_BLOCK):
    """Largest power-of-two block <= cap that divides n (falls back to n)."""
    b = min(cap, n)
    while b > 1:
        if n % b == 0:
            return b
        b //= 2
    return n


def population_step(params, v, w, rf, i_syn, i_ext, sfa_inc):
    """One 1 ms update of a whole rank population (wraps the L1 kernel)."""
    n = v.shape[0]
    block = pick_block(n)
    return lif_sfa_step(params, v, w, rf, i_syn, i_ext, sfa_inc, block=block)


def make_params(decay_v, decay_w, theta, v_reset, t_ref_steps, v_floor):
    """Pack model scalars into the params vector the kernel expects."""
    p = jnp.zeros((N_PARAMS,), jnp.float32)
    p = p.at[0].set(decay_v).at[1].set(decay_w).at[2].set(theta)
    p = p.at[3].set(v_reset).at[4].set(t_ref_steps).at[5].set(v_floor)
    return p


def lower_population_step(n):
    """Lower population_step for a population of n neurons; returns Lowered."""
    f32 = jnp.float32
    par = jax.ShapeDtypeStruct((N_PARAMS,), f32)
    vec = jax.ShapeDtypeStruct((n,), f32)
    return jax.jit(population_step).lower(par, vec, vec, vec, vec, vec, vec)


def population_step_packed(params, state, i_syn, i_ext, sfa_inc):
    """Packed-ABI variant for the rust hot path (EXPERIMENTS.md §Perf).

    The three state vectors travel as one f32[3n] buffer and the result as
    one f32[4n] = [v' | w' | rf' | spiked] buffer, so the rust runtime does
    a single host<->device copy each way and no tuple unwrapping::

        packed_step(params[8], state[3n], i_syn[n], i_ext[n], sfa_inc[n])
            -> f32[4n]
    """
    n = i_syn.shape[0]
    v, w, rf = state[:n], state[n:2 * n], state[2 * n:]
    v2, w2, rf2, sp = population_step(params, v, w, rf, i_syn, i_ext, sfa_inc)
    return jnp.concatenate([v2, w2, rf2, sp])


def lower_population_step_packed(n):
    """Lower the packed variant for a population of n neurons."""
    f32 = jnp.float32
    par = jax.ShapeDtypeStruct((N_PARAMS,), f32)
    st = jax.ShapeDtypeStruct((3 * n,), f32)
    vec = jax.ShapeDtypeStruct((n,), f32)
    return jax.jit(population_step_packed).lower(par, st, vec, vec, vec)
