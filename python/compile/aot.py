"""AOT compile path: lower the L2 population step to HLO text artifacts.

Run once via `make artifacts`; the rust runtime loads the resulting
`artifacts/lif_sfa_<n>.hlo.txt` files through the PJRT C API and Python is
never needed again.

Interchange format is HLO *text*, NOT `lowered.compile().serialize()` or a
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`). The text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/gen_hlo.py and its README).

A `manifest.json` records the size ladder and the ABI so the rust side can
pick the right artifact and verify its assumptions at load time.

Usage:  cd python && python -m compile.aot [--out-dir ../artifacts]
                                           [--sizes 1024,2048,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import (lower_population_step_packed, population_step,
                           population_step_packed, make_params)
from compile.kernels.lif_sfa import N_PARAMS, DEFAULT_BLOCK, vmem_bytes_per_block
from compile.kernels.ref import lif_sfa_step_ref

# Population-size ladder: rank populations are padded up to the nearest
# rung. Covers 20480/P for P = 1..256 (80 neurons/rank) up to a whole
# 32K-neuron rank.
DEFAULT_SIZES = [256, 512, 1024, 2048, 4096, 8192, 16384, 20480, 32768]


def to_hlo_text(lowered, return_tuple=False) -> str:
    """StableHLO -> XlaComputation -> HLO text.

    The packed ABI has a single array result, so we lower with
    return_tuple=False: the rust side then reads the output PjRtBuffer
    directly with copy_raw_to_host_sync (no tuple unwrap, §Perf).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def sanity_check(n: int) -> None:
    """Run the jitted steps (plain + packed) against the pure-jnp oracle."""
    rng = np.random.default_rng(n)
    params = make_params(0.95, 0.998, 20.0, 0.0, 2.0, -40.0)
    args = [params] + [
        jnp.asarray(rng.normal(0.0, 5.0, n).astype(np.float32)) for _ in range(3)
    ] + [
        jnp.asarray(rng.normal(0.0, 2.0, n).astype(np.float32)) for _ in range(2)
    ] + [jnp.full((n,), 0.3, jnp.float32)]
    got = population_step(*args)
    want = lif_sfa_step_ref(*args)
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_), rtol=1e-6, atol=1e-6)
    state = jnp.concatenate(args[1:4])
    packed = population_step_packed(params, state, *args[4:])
    np.testing.assert_array_equal(
        np.asarray(packed), np.concatenate([np.asarray(x) for x in got])
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(str(s) for s in DEFAULT_SIZES))
    ap.add_argument("--skip-check", action="store_true")
    args = ap.parse_args()

    sizes = sorted({int(s) for s in args.sizes.split(",") if s})
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for n in sizes:
        if not args.skip_check:
            sanity_check(n)
        lowered = lower_population_step_packed(n)
        text = to_hlo_text(lowered, return_tuple=False)
        name = f"lif_sfa_{n}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        entries.append({"n": n, "file": name, "bytes": len(text)})
        print(f"  lif_sfa n={n:>6} -> {name} ({len(text)} chars)")

    manifest = {
        "kernel": "lif_sfa",
        "abi": {
            "version": 2,
            "inputs": ["params[8]", "state[3n] = v|w|rf", "i_syn[n]",
                       "i_ext[n]", "sfa_inc[n]"],
            "outputs": ["packed[4n] = v|w|rf|spiked"],
            "dtype": "f32",
            "n_params": N_PARAMS,
            "param_names": ["decay_v", "decay_w", "theta", "v_reset",
                            "t_ref_steps", "v_floor", "pad", "pad"],
            "return_tuple": False,
        },
        "block": DEFAULT_BLOCK,
        "vmem_bytes_per_block": vmem_bytes_per_block(),
        "sizes": entries,
        "jax_version": jax.__version__,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(sizes)} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
