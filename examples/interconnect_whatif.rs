//! What-if ablation: the paper's conclusion argues that "the design of
//! low-latency, energy-efficient interconnects supporting collective
//! communications is of primary importance". This example quantifies it:
//! replay the same workload over the commodity fabrics and over an
//! ExaNeSt-class low-latency interconnect, and report the largest network
//! each can simulate in soft real-time.
//!
//! ```bash
//! cargo run --release --example interconnect_whatif
//! ```

use dpsnn::config::{ConnectivityMode, Mode, NetworkParams, RunConfig, Topology};
use dpsnn::coordinator;
use dpsnn::metrics::memory;
use dpsnn::platform::presets::platform_by_name;
use dpsnn::simnet::presets::IB;
use dpsnn::simnet::{AllToAllModel, LinkModel};
use dpsnn::util::table::Table;

/// ~2 spikes/rank/step near the real-time point: the latency-dominated
/// payload regime of the paper's Fig 2.
const SPIKE_MSG_BYTES: u64 = 25;

/// Smallest process count (doubling sweep) where the node-leader
/// hierarchical exchange beats the flat one on this model.
fn hier_crossover(model: &AllToAllModel) -> Option<u32> {
    let mut p = 2u32;
    while p <= 1024 {
        let flat = model.exchange_time(p, SPIKE_MSG_BYTES).total();
        let hier = model.exchange_time_hierarchical(p, SPIKE_MSG_BYTES).total();
        if hier < flat {
            return Some(p);
        }
        p *= 2;
    }
    None
}

/// Smallest process count (doubling sweep) where the L-level tree
/// exchange over per-tier `links` beats the flat one.
fn tree_crossover(
    model: &AllToAllModel,
    shape: &[u32],
    links: &[LinkModel],
) -> Option<u32> {
    let mut p = 2u32;
    while p <= 1024 {
        let flat = model.exchange_time(p, SPIKE_MSG_BYTES).total();
        let tree = model
            .exchange_time_tree(p, SPIKE_MSG_BYTES, shape, links)
            .total();
        if tree < flat {
            return Some(p);
        }
        p *= 2;
    }
    None
}

fn wall(net: NetworkParams, ic: &str, procs: u32) -> anyhow::Result<f64> {
    let mut cfg = RunConfig::default();
    cfg.net = net;
    cfg.procs = procs;
    cfg.sim_seconds = 10.0;
    cfg.mode = Mode::Modeled;
    cfg.platform = "xeon".into();
    cfg.interconnect = ic.into();
    Ok(coordinator::run(&cfg)?.wall_s)
}

/// Soft-real-time acceptance: within the timing model's documented
/// ~±25% residual of the 10 s threshold (EXPERIMENTS.md).
const RT_WALL_S: f64 = 12.0;

/// Largest paper-family network (xN of 20480) real-time capable on `ic`.
fn realtime_capacity(ic: &str) -> anyhow::Result<(u32, u32, f64)> {
    let mut best = (0u32, 0u32, f64::MAX);
    for scale in [1u32, 2, 4, 8, 16] {
        let n = 20_480 * scale;
        for procs in [16u32, 32, 64, 128, 256] {
            let w = wall(NetworkParams::paper(n), ic, procs)?;
            if w <= RT_WALL_S && (n > best.0 || (n == best.0 && w < best.2)) {
                best = (n, procs, w);
            }
        }
    }
    Ok(best)
}

fn main() -> anyhow::Result<()> {
    let mut sweep = Table::new(
        "20480N wall-clock (s / 10 s sim) by interconnect and procs (modeled, xeon)",
        &["procs", "eth1g", "ib", "exanest"],
    );
    for procs in [4u32, 16, 32, 64, 128, 256] {
        sweep.row(vec![
            procs.to_string(),
            format!("{:.1}", wall(NetworkParams::paper_20480(), "eth1g", procs)?),
            format!("{:.1}", wall(NetworkParams::paper_20480(), "ib", procs)?),
            format!("{:.1}", wall(NetworkParams::paper_20480(), "exanest", procs)?),
        ]);
    }
    println!("{}", sweep.render());
    sweep.write_csv(std::path::Path::new("results/interconnect_whatif.csv"))?;

    let mut cap = Table::new(
        "largest real-time-capable network per fabric",
        &["fabric", "neurons", "at procs", "wall (s/10s)"],
    );
    for ic in ["eth1g", "ib", "exanest"] {
        let (n, p, w) = realtime_capacity(ic)?;
        cap.row(vec![
            ic.to_string(),
            if n == 0 { "none".into() } else { n.to_string() },
            p.to_string(),
            if n == 0 { "-".into() } else { format!("{w:.1}") },
        ]);
    }
    println!("{}", cap.render());

    // Topology what-if: how much of the latency wall does node-leader
    // aggregation (--topology nodes:<k>) claw back, per node packing?
    let rpns = [1u32, 4, 8, 16];
    let mut topo = Table::new(
        "flat/hier exchange-time ratio (IB, 25 B/pair/step) by ranks-per-node",
        &["procs", "rpn=1", "rpn=4", "rpn=8", "rpn=16"],
    );
    for procs in [4u32, 8, 16, 32, 64, 128, 256, 512] {
        let mut row = vec![procs.to_string()];
        for rpn in rpns {
            let m = AllToAllModel::new(IB, rpn);
            let flat = m.exchange_time(procs, SPIKE_MSG_BYTES).total();
            let hier = m.exchange_time_hierarchical(procs, SPIKE_MSG_BYTES).total();
            let cell = if hier > 0.0 {
                format!("{:.1}x", flat / hier)
            } else {
                "-".into()
            };
            row.push(cell);
        }
        topo.row(row);
    }
    println!("{}", topo.render());
    topo.write_csv(std::path::Path::new(
        "results/interconnect_whatif_topology.csv",
    ))?;
    for rpn in rpns {
        let m = AllToAllModel::new(IB, rpn);
        match hier_crossover(&m) {
            Some(p) => println!(
                "rpn={rpn:>2}: hierarchy beats flat from P={p} \
                 ({} fabric msgs/exchange vs flat {})",
                m.hierarchical_inter_messages(p),
                m.flat_inter_messages(p),
            ),
            None => println!(
                "rpn={rpn:>2}: hierarchy never beats flat up to P=1024 \
                 (single-rank nodes only add framing)"
            ),
        }
    }

    // Multi-tier what-if: sweep board → chassis → rack shapes with the
    // xeon platform's per-tier link derating (each tier above the
    // board link costs more latency and less bandwidth) and predict
    // the crossover P where each tree starts beating the flat
    // exchange — and where a DEEPER hierarchy starts beating a
    // shallower one.
    let platform = platform_by_name("xeon")?;
    let shapes: &[&[u32]] = &[&[16], &[16, 4], &[4, 4, 4]];
    let mut tiers = Table::new(
        "flat/tree exchange-time ratio (IB base + per-tier derating, 25 B/pair/step)",
        &["procs", "tree:16", "tree:16,4", "tree:4,4,4"],
    );
    for procs in [8u32, 16, 32, 64, 128, 256, 512, 1024] {
        let mut row = vec![procs.to_string()];
        for shape in shapes {
            let m = AllToAllModel::new(IB, shape[0]);
            let links = platform.tree_links(IB, shape.len());
            let flat = m.exchange_time(procs, SPIKE_MSG_BYTES).total();
            let tree = m
                .exchange_time_tree(procs, SPIKE_MSG_BYTES, shape, &links)
                .total();
            row.push(if tree > 0.0 {
                format!("{:.1}x", flat / tree)
            } else {
                "-".into()
            });
        }
        tiers.row(row);
    }
    println!("{}", tiers.render());
    tiers.write_csv(std::path::Path::new(
        "results/interconnect_whatif_tiers.csv",
    ))?;
    for shape in shapes {
        let m = AllToAllModel::new(IB, shape[0]);
        let links = platform.tree_links(IB, shape.len());
        let label: Vec<String> = shape.iter().map(|k| k.to_string()).collect();
        match tree_crossover(&m, shape, &links) {
            Some(p) => println!(
                "tree:{}: beats flat from P={p} ({} fabric msgs/exchange, \
                 {} on the top tier, vs flat {})",
                label.join(","),
                m.tree_fabric_messages(p, shape),
                m.tree_level_messages(p, shape).last().copied().unwrap_or(0),
                m.flat_inter_messages(p),
            ),
            None => println!(
                "tree:{}: never beats flat up to P=1024 on this fabric",
                label.join(","),
            ),
        }
    }
    // deeper-vs-shallower: one machine, two topology descriptors. The
    // rack fabric keeps IB-class bandwidth but pays 10x the latency
    // per message (long-haul switch stages); the chassis tier is IB.
    // tree:16 puts every board pair straight on the rack fabric;
    // tree:16,4 inserts the chassis tier so only chassis pairs cross
    // the slow link. Where the deeper descriptor wins is the paper's
    // "design the interconnect hierarchy" question made concrete.
    let rack = LinkModel {
        alpha_s: IB.alpha_s * 10.0,
        fabric_msg_cost_s: IB.fabric_msg_cost_s * 10.0,
        ..IB
    };
    let m = AllToAllModel::new(IB, 16);
    let mut deeper_at = None;
    let mut p = 2u32;
    while p <= 1024 {
        let t2 = m
            .exchange_time_tree(p, SPIKE_MSG_BYTES, &[16], &[rack])
            .total();
        let t3 = m
            .exchange_time_tree(p, SPIKE_MSG_BYTES, &[16, 4], &[IB, rack])
            .total();
        if t3 < t2 && deeper_at.is_none() {
            deeper_at = Some(p);
        }
        p *= 2;
    }
    match deeper_at {
        Some(p) => println!(
            "tree:16,4 beats tree:16 from P={p} on a latency-poor rack \
             fabric: the chassis tier's aggregation outweighs its extra \
             store-and-forward hop"
        ),
        None => println!(
            "tree:16,4 never beats tree:16 up to P=1024 on this fabric"
        ),
    }
    println!(
        "the paper's thesis quantified: lower fabric latency — or a topology\n\
         that aggregates before touching the fabric, at every tier of the\n\
         board → chassis → rack hierarchy — directly buys real-time\n\
         capacity for larger cortical fields."
    );

    // Memory what-if at the 100x point: 2M neurons, priced through the
    // tree model. Below ~8 ranks the materialized synapse table alone
    // busts a 2 GiB/rank budget — the run cannot even build — while
    // the procedural store stays O(state) at any P, so the fabric, not
    // DRAM, remains the scaling limit `--connectivity auto` exposes.
    let big = NetworkParams::paper(2_000_000);
    let mut memtbl = Table::new(
        "2M-neuron per-rank memory (largest even-split rank) and tree:16,4 wall",
        &["procs", "mat GB/rk", "proc MB/rk", "auto picks", "wall (s/10s)"],
    );
    for procs in [1u32, 4, 16, 64, 256] {
        let n_local = big.n_neurons.div_ceil(procs);
        let mat = memory::predicted_rank_bytes(&big, n_local, ConnectivityMode::Materialized);
        let pro = memory::predicted_rank_bytes(&big, n_local, ConnectivityMode::Procedural);
        let auto = memory::auto_connectivity_mode(&big, procs, memory::DEFAULT_RANK_BUDGET_BYTES);
        let mut cfg = RunConfig::default();
        cfg.net = big.clone();
        cfg.procs = procs;
        cfg.sim_seconds = 10.0;
        cfg.mode = Mode::Modeled;
        cfg.platform = "xeon".into();
        cfg.interconnect = "ib".into();
        cfg.topology = "tree:16,4".parse::<Topology>()?;
        let wall = coordinator::run(&cfg)?.wall_s;
        memtbl.row(vec![
            procs.to_string(),
            format!("{:.2}", mat as f64 / 1e9),
            format!("{:.1}", pro as f64 / 1e6),
            auto.to_string(),
            format!("{wall:.1}"),
        ]);
    }
    println!("{}", memtbl.render());
    memtbl.write_csv(std::path::Path::new(
        "results/interconnect_whatif_memory.csv",
    ))?;
    println!(
        "procedural connectivity decouples network size from per-rank DRAM:\n\
         the 2M-neuron table needs {:.1} GB on one rank, the procedural\n\
         generator a constant {} B — memory stops being the reason to scale\n\
         out before the interconnect says so.",
        memory::predicted_rank_bytes(&big, big.n_neurons, ConnectivityMode::Materialized) as f64
            / 1e9,
        memory::procedural_synapse_bytes(1),
    );
    Ok(())
}
