//! What-if ablation: the paper's conclusion argues that "the design of
//! low-latency, energy-efficient interconnects supporting collective
//! communications is of primary importance". This example quantifies it:
//! replay the same workload over the commodity fabrics and over an
//! ExaNeSt-class low-latency interconnect, and report the largest network
//! each can simulate in soft real-time.
//!
//! ```bash
//! cargo run --release --example interconnect_whatif
//! ```

use dpsnn::config::{Mode, NetworkParams, RunConfig};
use dpsnn::coordinator;
use dpsnn::util::table::Table;

fn wall(net: NetworkParams, ic: &str, procs: u32) -> anyhow::Result<f64> {
    let mut cfg = RunConfig::default();
    cfg.net = net;
    cfg.procs = procs;
    cfg.sim_seconds = 10.0;
    cfg.mode = Mode::Modeled;
    cfg.platform = "xeon".into();
    cfg.interconnect = ic.into();
    Ok(coordinator::run(&cfg)?.wall_s)
}

/// Soft-real-time acceptance: within the timing model's documented
/// ~±25% residual of the 10 s threshold (EXPERIMENTS.md).
const RT_WALL_S: f64 = 12.0;

/// Largest paper-family network (xN of 20480) real-time capable on `ic`.
fn realtime_capacity(ic: &str) -> anyhow::Result<(u32, u32, f64)> {
    let mut best = (0u32, 0u32, f64::MAX);
    for scale in [1u32, 2, 4, 8, 16] {
        let n = 20_480 * scale;
        for procs in [16u32, 32, 64, 128, 256] {
            let w = wall(NetworkParams::paper(n), ic, procs)?;
            if w <= RT_WALL_S && (n > best.0 || (n == best.0 && w < best.2)) {
                best = (n, procs, w);
            }
        }
    }
    Ok(best)
}

fn main() -> anyhow::Result<()> {
    let mut sweep = Table::new(
        "20480N wall-clock (s / 10 s sim) by interconnect and procs (modeled, xeon)",
        &["procs", "eth1g", "ib", "exanest"],
    );
    for procs in [4u32, 16, 32, 64, 128, 256] {
        sweep.row(vec![
            procs.to_string(),
            format!("{:.1}", wall(NetworkParams::paper_20480(), "eth1g", procs)?),
            format!("{:.1}", wall(NetworkParams::paper_20480(), "ib", procs)?),
            format!("{:.1}", wall(NetworkParams::paper_20480(), "exanest", procs)?),
        ]);
    }
    println!("{}", sweep.render());
    sweep.write_csv(std::path::Path::new("results/interconnect_whatif.csv"))?;

    let mut cap = Table::new(
        "largest real-time-capable network per fabric",
        &["fabric", "neurons", "at procs", "wall (s/10s)"],
    );
    for ic in ["eth1g", "ib", "exanest"] {
        let (n, p, w) = realtime_capacity(ic)?;
        cap.row(vec![
            ic.to_string(),
            if n == 0 { "none".into() } else { n.to_string() },
            p.to_string(),
            if n == 0 { "-".into() } else { format!("{w:.1}") },
        ]);
    }
    println!("{}", cap.render());
    println!(
        "the paper's thesis quantified: lower fabric latency directly buys\n\
         real-time capacity for larger cortical fields."
    );
    Ok(())
}
