//! Brain-state demo: the same cortical network expresses an asynchronous
//! awake-like (AW) regime or deep-sleep-like Slow Wave Activity (SWA)
//! "by tuning the values of SFA and stimulation" (paper §II, the
//! WaveScalES use case). Runs both live and classifies the regimes.
//!
//! ```bash
//! cargo run --release --example awake_vs_swa
//! ```

use dpsnn::config::{Mode, NetworkParams, RunConfig};
use dpsnn::coordinator;
use dpsnn::stats::rates::RateMonitor;
use dpsnn::stats::regime::{classify_regime, Regime};

fn run_regime(name: &str, net: NetworkParams, seconds: f64) -> anyhow::Result<Regime> {
    let mut cfg = RunConfig::default();
    cfg.net = net;
    cfg.procs = 4;
    cfg.sim_seconds = seconds;
    cfg.mode = Mode::Live;
    let r = coordinator::run(&cfg)?;

    let mut m = RateMonitor::new(cfg.net.n_neurons, cfg.net.dt_ms);
    for &c in &r.pop_counts {
        m.record(c);
    }
    let skip = m.steps() / 4;
    let regime = classify_regime(&m, 50, skip);
    println!(
        "\n=== {name}: mean rate {:.2} Hz, rate CV {:.2}, regime {:?} ===",
        m.steady_rate_hz(skip),
        m.rate_cv(50, skip),
        regime
    );
    // 100 ms-binned population rate sparkline
    let series = m.rate_series_hz(100);
    let peak = series.iter().cloned().fold(1e-9, f64::max);
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#'];
    let line: String = series
        .iter()
        .map(|&r| glyphs[((r / peak) * 7.0).round() as usize])
        .collect();
    println!("rate trace (100 ms bins): [{line}]");
    Ok(regime)
}

fn main() -> anyhow::Result<()> {
    let n = 4096;

    // Awake: the default calibration — steady external drive, mild SFA.
    let awake = NetworkParams::tiny(n);

    // Deep sleep: strong fatigue + weaker external bath pushes the
    // network into Up/Down alternation (slow oscillations).
    let mut swa = NetworkParams::tiny(n);
    swa.sfa_inc = dpsnn::config::network::quantize_weight(1.50);
    swa.tau_w_ms = 800.0;
    swa.ext_rate_hz = 1.6;
    swa.j_exc = dpsnn::config::network::quantize_weight(0.75);

    let r_awake = run_regime("AW  (awake-like)", awake, 6.0)?;
    let r_swa = run_regime("SWA (deep-sleep-like)", swa, 6.0)?;

    println!(
        "\nclassified: AW -> {:?}, SWA -> {:?}",
        r_awake, r_swa
    );
    if r_awake == Regime::AsynchronousAwake && r_swa == Regime::SlowWave {
        println!("both regimes expressed by the same network, as in the paper.");
    } else {
        println!("note: regime classification differs from target (tuning-sensitive).");
    }
    Ok(())
}
