//! Energy study: sweep platforms × interconnects × core counts through
//! the modeled pipeline and chart the paper's central trade-off — the
//! energy-to-solution minimum at intermediate parallelism, the IB-vs-ETH
//! gap, and the ARM-vs-Intel efficiency/speed trade.
//!
//! ```bash
//! cargo run --release --example energy_study
//! ```

use dpsnn::config::{Mode, NetworkParams, RunConfig};
use dpsnn::coordinator;
use dpsnn::util::table::{ascii_chart, Table};

fn run(platform: &str, interconnect: &str, procs: u32) -> anyhow::Result<(f64, f64, f64)> {
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::paper_20480();
    cfg.procs = procs;
    cfg.sim_seconds = 10.0;
    cfg.mode = Mode::Modeled;
    cfg.platform = platform.into();
    cfg.interconnect = interconnect.into();
    let r = coordinator::run(&cfg)?;
    let e = r.energy.unwrap();
    Ok((r.wall_s, e.energy_j, e.uj_per_syn_event))
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Energy-to-solution, 20480N x 10 s (modeled)",
        &["platform", "cores", "time (s)", "energy (J)", "uJ/syn event"],
    );
    let mut series: Vec<(&str, Vec<(f64, f64)>)> = Vec::new();

    let sweeps: &[(&str, &str, &[u32])] = &[
        ("westmere", "ib", &[1, 2, 4, 8, 16, 32, 64]),
        ("westmere", "eth1g", &[32, 64]),
        ("jetson", "eth1g", &[1, 2, 4, 8]),
        ("trenz", "eth1g", &[1, 2, 4, 8, 16]),
    ];
    for (platform, ic, procs) in sweeps {
        let mut pts = Vec::new();
        for &p in *procs {
            let (t, e, uj) = run(platform, ic, p)?;
            table.row(vec![
                format!("{platform}+{ic}"),
                p.to_string(),
                format!("{t:.1}"),
                format!("{e:.0}"),
                format!("{uj:.2}"),
            ]);
            pts.push((p as f64, e));
        }
        series.push((
            match (*platform, *ic) {
                ("westmere", "ib") => "x86+IB",
                ("westmere", _) => "x86+ETH",
                ("jetson", _) => "jetson",
                _ => "trenz",
            },
            pts,
        ));
    }

    println!("{}", table.render());
    println!(
        "{}",
        ascii_chart(
            "energy-to-solution vs cores (log-log): note the x86 minimum at ~8",
            &series,
            true,
            true,
            60,
            16,
        )
    );
    table.write_csv(std::path::Path::new("results/energy_study.csv"))?;

    // The paper's conclusion in one line:
    let (t_arm, e_arm, uj_arm) = run("jetson", "eth1g", 4)?;
    let (t_x86, e_x86, uj_x86) = run("westmere", "ib", 4)?;
    println!(
        "ARM vs Intel at 4 cores: {:.1}x slower, {:.1}x less energy \
         ({uj_arm:.2} vs {uj_x86:.2} uJ/syn-event; paper: ~5x slower, ~3x cheaper)",
        t_arm / t_x86,
        e_x86 / e_arm,
    );
    let _ = (e_arm, e_x86);
    Ok(())
}
