//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Runs the paper's real workload — the 20480-neuron / 2.3e7-synapse
//! benchmark network, 10 s of activity — live on this host across a
//! process sweep, reporting the paper's headline metrics: wall-clock vs
//! the soft real-time threshold and the comp/comm/barrier decomposition.
//! This exercises every layer: connectivity generation, delay rings, AER
//! packing, the all-to-all transport, the barrier, the profiler, and the
//! LIF+SFA backend (pass `--backend xla` for the AOT/PJRT path after
//! `make artifacts`).
//!
//! ```bash
//! cargo run --release --example realtime_scaling -- [--seconds S] [--max-procs P]
//! ```

use dpsnn::config::{Mode, NetworkParams, RunConfig};
use dpsnn::coordinator;
use dpsnn::util::cli::Args;
use dpsnn::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let seconds: f64 = args.get_or("seconds", 10.0)?;
    let host_cores = std::thread::available_parallelism()?.get() as u32;
    let max_procs: u32 = args.get_or("max-procs", host_cores)?;
    let backend = args.get_or("backend", "native".to_string())?;

    let mut table = Table::new(
        &format!(
            "20480N live strong scaling on this host ({} s simulated, {} backend)",
            seconds, backend
        ),
        &[
            "procs", "wall (s)", "x real-time", "rate (Hz)", "comp %", "comm %",
            "barrier %",
        ],
    );

    let mut procs = 1u32;
    while procs <= max_procs {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::paper_20480();
        cfg.procs = procs;
        cfg.sim_seconds = seconds;
        cfg.mode = Mode::Live;
        cfg.backend = backend.parse()?;
        let r = coordinator::run(&cfg)?;
        let (comp, comm, barrier) = r.components.fractions();
        table.row(vec![
            procs.to_string(),
            format!("{:.2}", r.wall_s),
            format!(
                "{:.2}{}",
                r.realtime_factor(),
                if r.is_realtime() { " RT" } else { "" }
            ),
            format!("{:.2}", r.mean_rate_hz),
            format!("{:.1}", comp * 100.0),
            format!("{:.1}", comm * 100.0),
            format!("{:.1}", barrier * 100.0),
        ]);
        eprintln!(
            "  P={procs}: wall {:.2} s (x{:.2} real-time), rate {:.2} Hz",
            r.wall_s,
            r.realtime_factor(),
            r.mean_rate_hz
        );
        procs *= 2;
    }

    println!("\n{}", table.render());
    table.write_csv(std::path::Path::new("results/realtime_scaling_live.csv"))?;
    println!("CSV written to results/realtime_scaling_live.csv");
    Ok(())
}
