//! Quickstart: simulate a small cortical network live on this host and
//! print the paper-style profile.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dpsnn::config::{Backend, Mode, NetworkParams, RunConfig};
use dpsnn::coordinator;

fn main() -> anyhow::Result<()> {
    // A 4096-neuron down-scale of the paper's benchmark network:
    // 80% excitatory LIF with spike-frequency adaptation, 20% inhibitory,
    // external 400-synapse 3 Hz Poisson bath, 1 ms spike exchange.
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::tiny(4096);
    cfg.procs = 4;
    cfg.sim_seconds = 2.0;
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Live;

    println!(
        "simulating {} neurons / {} synapses for {} s on {} ranks...\n",
        cfg.net.n_neurons,
        cfg.net.total_synapses(),
        cfg.sim_seconds,
        cfg.procs
    );
    let result = coordinator::run(&cfg)?;
    println!("{}", result.summary());

    // The same run, partitioned differently, produces the identical spike
    // raster — the property that makes the paper's strong-scaling sweeps
    // compare like with like.
    cfg.procs = 1;
    let single = coordinator::run(&cfg)?;
    assert_eq!(single.total_spikes, result.total_spikes);
    println!(
        "partition independence: 1-rank and 4-rank runs both produced {} spikes",
        result.total_spikes
    );
    Ok(())
}
